"""Recurrent sequence mixers: Mamba (Jamba) and mLSTM/sLSTM (xLSTM).

Each mixer provides:
  * ``apply_train``  — full-sequence form (associative scan for Mamba,
    stabilized quadratic parallel form for mLSTM, time scan for sLSTM),
  * ``init_state`` / ``apply_decode`` — O(1)-per-token recurrent stepping
    used by the serving path (this is what makes ``long_500k`` feasible).

Train and decode forms are validated against each other in tests.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers


# ---------------------------------------------------------------------------
# Mamba (selective SSM) — Jamba's sequence mixer
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MambaSpec:
    d_model: int
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model


def mamba_init(key, spec: MambaSpec, dtype) -> dict:
    ks = jax.random.split(key, 6)
    di, ds = spec.d_inner, spec.d_state
    # S4D-real initialization for A (negative reals).
    a = jnp.tile(jnp.arange(1, ds + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": layers.dense_init(ks[0], spec.d_model, 2 * di, dtype),
        "conv": layers.truncated_normal_init(
            ks[1], (spec.d_conv, di), spec.d_conv**-0.5, dtype
        ),
        "conv_bias": jnp.zeros((di,), dtype),
        "x_proj": layers.dense_init(ks[2], di, ds * 2 + 1, dtype),
        "dt_bias": jnp.asarray(
            jnp.log(jnp.expm1(jnp.full((di,), 1e-2))), dtype
        ),
        "dt_proj": layers.dense_init(ks[3], 1, di, dtype),
        "a_log": jnp.log(a).astype(jnp.float32),  # keep fp32 (sensitive)
        "d_skip": jnp.ones((di,), dtype),
        "out_proj": layers.dense_init(ks[4], di, spec.d_model, dtype),
    }


def _mamba_gates(params, u, spec: MambaSpec):
    """Shared input-dependent SSM parameters. u: [B, S, d_inner] post-conv."""
    proj = layers.dense_apply(params["x_proj"], u, jnp.float32)
    dt_raw, bmat, cmat = jnp.split(
        proj, [1, 1 + spec.d_state], axis=-1
    )  # [B,S,1], [B,S,ds], [B,S,ds]
    dt = jax.nn.softplus(
        layers.dense_apply(params["dt_proj"], dt_raw, jnp.float32)
        + params["dt_bias"].astype(jnp.float32)
    )  # [B,S,di]
    a = -jnp.exp(params["a_log"])  # [di, ds]
    decay = jnp.exp(dt[..., None] * a)  # [B,S,di,ds]
    drive = dt[..., None] * bmat[..., None, :]  # [B,S,di,ds]
    return decay, drive, cmat


def mamba_apply_train(params, x, spec: MambaSpec, compute_dtype):
    """x: [B, S, D] -> [B, S, D] via associative scan over time."""
    b, s, _ = x.shape
    xz = layers.dense_apply(params["in_proj"], x, compute_dtype)
    u, z = jnp.split(xz, 2, axis=-1)  # [B,S,di] each
    # Depthwise causal conv along time.
    w = params["conv"].astype(compute_dtype)  # [d_conv, di]
    upad = jnp.pad(u, ((0, 0), (spec.d_conv - 1, 0), (0, 0)))
    uc = sum(
        w[i] * jax.lax.dynamic_slice_in_dim(upad, i, s, axis=1)
        for i in range(spec.d_conv)
    ) + params["conv_bias"].astype(compute_dtype)
    uc = jax.nn.silu(uc)

    decay, drive, cmat = _mamba_gates(params, uc, spec)
    bu = drive * uc.astype(jnp.float32)[..., None]  # [B,S,di,ds]

    # h_t = decay_t * h_{t-1} + bu_t  — associative scan over S.
    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, br + ar * bl

    _, h = jax.lax.associative_scan(combine, (decay, bu), axis=1)
    y = jnp.einsum("bsdn,bsn->bsd", h, cmat)
    y = y.astype(compute_dtype) + params["d_skip"].astype(compute_dtype) * uc
    y = y * jax.nn.silu(z)
    return layers.dense_apply(params["out_proj"], y, compute_dtype)


def mamba_init_state(batch: int, spec: MambaSpec, dtype) -> dict:
    return {
        "conv": jnp.zeros((batch, spec.d_conv - 1, spec.d_inner), dtype),
        "ssm": jnp.zeros((batch, spec.d_inner, spec.d_state), jnp.float32),
    }


def mamba_apply_decode(params, x, state, spec: MambaSpec, compute_dtype):
    """Single-step recurrence. x: [B, 1, D]."""
    b = x.shape[0]
    xz = layers.dense_apply(params["in_proj"], x, compute_dtype)
    u, z = jnp.split(xz, 2, axis=-1)  # [B,1,di]
    hist = jnp.concatenate([state["conv"], u], axis=1)  # [B,d_conv,di]
    w = params["conv"].astype(compute_dtype)
    uc = jnp.einsum("bcd,cd->bd", hist, w) + params["conv_bias"].astype(
        compute_dtype
    )
    uc = jax.nn.silu(uc)[:, None, :]  # [B,1,di]

    decay, drive, cmat = _mamba_gates(params, uc, spec)
    h = (
        state["ssm"] * decay[:, 0]
        + drive[:, 0] * uc.astype(jnp.float32)[:, 0, :, None]
    )
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None, :]
    y = y.astype(compute_dtype) + params["d_skip"].astype(compute_dtype) * uc
    y = y * jax.nn.silu(z)
    out = layers.dense_apply(params["out_proj"], y, compute_dtype)
    return out, {"conv": hist[:, 1:], "ssm": h}


# ---------------------------------------------------------------------------
# mLSTM (xLSTM's matrix-memory block) — parallel + recurrent forms
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MLSTMSpec:
    d_model: int
    num_heads: int
    proj_factor: int = 2  # d_inner = proj_factor · d_model (xLSTM block)

    @property
    def d_inner(self) -> int:
        return self.proj_factor * self.d_model

    @property
    def head_dim(self) -> int:
        return self.d_inner // self.num_heads


def mlstm_init(key, spec: MLSTMSpec, dtype) -> dict:
    """xLSTM mLSTM block: up-proj (x, z), per-head block-diagonal q/k/v,
    exponential gates, matrix memory, gated down-proj."""
    ks = jax.random.split(key, 7)
    d, di, h, hd = spec.d_model, spec.d_inner, spec.num_heads, spec.head_dim
    blockdiag = lambda k: layers.truncated_normal_init(
        k, (h, hd, hd), hd**-0.5, dtype
    )
    return {
        "up": layers.dense_init(ks[0], d, 2 * di, dtype),
        "wq": blockdiag(ks[1]),
        "wk": blockdiag(ks[2]),
        "wv": blockdiag(ks[3]),
        "wi": layers.dense_init_bias(ks[4], d, spec.num_heads, dtype),
        "wf": layers.dense_init_bias(ks[5], d, spec.num_heads, dtype),
        "down": layers.dense_init(ks[6], di, d, dtype),
    }


def _mlstm_qkv(params, x, spec: MLSTMSpec, compute_dtype):
    """Returns q,k,v in head space plus gates and the z gating stream."""
    b, s, d = x.shape
    h, hd = spec.num_heads, spec.head_dim
    xz = layers.dense_apply(params["up"], x, compute_dtype)
    xin, z = jnp.split(xz, 2, axis=-1)  # [b,s,di] each
    xh = xin.reshape(b, s, h, hd)
    q = jnp.einsum("bshd,hde->bshe", xh, params["wq"].astype(compute_dtype))
    k = jnp.einsum(
        "bshd,hde->bshe", xh, params["wk"].astype(compute_dtype)
    ) * (hd**-0.5)
    v = jnp.einsum("bshd,hde->bshe", xh, params["wv"].astype(compute_dtype))
    igate = layers.dense_apply(params["wi"], x, jnp.float32)  # [b,s,h]
    fgate = layers.dense_apply(params["wf"], x, jnp.float32)
    return q, k, v, igate, fgate, z


def mlstm_apply_train(params, x, spec: MLSTMSpec, compute_dtype):
    """Stabilized parallel (quadratic) form of mLSTM (xLSTM paper, eq. 2x).

    D_ij = exp(logσ(f) cumulative + i_j − m_i); attention-like weighted sum
    with per-row max-stabilizer m and normalizer max(|sum|, exp(-m)).
    """
    b, s, d = x.shape
    q, k, v, igate, fgate, z = _mlstm_qkv(params, x, spec, compute_dtype)
    logf = jax.nn.log_sigmoid(fgate)  # [b,s,h]
    fcum = jnp.cumsum(logf, axis=1)
    # log decay from j -> i (i >= j): fcum_i − fcum_j  (exclusive of f_j? —
    # state at j includes i_j then decays by f_{j+1..i}).
    dmat = fcum[:, :, None, :] - fcum[:, None, :, :]  # [b, i, j, h]
    dmat = dmat + igate[:, None, :, :]  # + i_j
    causal = jnp.tril(jnp.ones((s, s), bool))
    dmat = jnp.where(causal[None, :, :, None], dmat, -jnp.inf)
    m = jnp.max(dmat, axis=2, keepdims=True)  # [b, i, 1, h]
    dexp = jnp.exp(dmat - m)  # stabilized decay weights
    scores = jnp.einsum(
        "bihd,bjhd->bijh", q.astype(jnp.float32), k.astype(jnp.float32)
    )
    wts = scores * dexp
    norm = jnp.maximum(
        jnp.abs(jnp.sum(wts, axis=2)), jnp.exp(-m[:, :, 0, :])
    )  # [b,i,h]
    y = jnp.einsum("bijh,bjhd->bihd", wts, v.astype(jnp.float32))
    y = (y / (norm[..., None] + 1e-6)).astype(compute_dtype)
    y = y.reshape(b, s, spec.d_inner) * jax.nn.silu(z)
    return layers.dense_apply(params["down"], y, compute_dtype)


def mlstm_init_state(batch: int, spec: MLSTMSpec, dtype) -> dict:
    h, hd = spec.num_heads, spec.head_dim
    return {
        "c": jnp.zeros((batch, h, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, h, hd), jnp.float32),
        "m": jnp.full((batch, h), -jnp.inf, jnp.float32),
    }


def mlstm_apply_decode(params, x, state, spec: MLSTMSpec, compute_dtype):
    """Recurrent mLSTM step (xLSTM paper eqs. 19-27). x: [B, 1, D]."""
    b, _, d = x.shape
    q, k, v, igate, fgate, z = _mlstm_qkv(params, x, spec, compute_dtype)
    q, k, v = q[:, 0], k[:, 0], v[:, 0]  # [b,h,hd]
    i_t, f_t = igate[:, 0], fgate[:, 0]  # [b,h]
    logf = jax.nn.log_sigmoid(f_t)
    m_new = jnp.maximum(logf + state["m"], i_t)
    fw = jnp.exp(logf + state["m"] - m_new)[..., None]
    iw = jnp.exp(i_t - m_new)[..., None]
    kf, vf = k.astype(jnp.float32), v.astype(jnp.float32)
    c = state["c"] * fw[..., None] + iw[..., None] * (
        kf[..., :, None] * vf[..., None, :]
    )
    n = state["n"] * fw + iw * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhd,bhde->bhe", qf, c)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", qf, n)), jnp.exp(-m_new)
    )
    y = (num / (den[..., None] + 1e-6)).astype(compute_dtype)
    y = y.reshape(b, 1, spec.d_inner) * jax.nn.silu(z)
    out = layers.dense_apply(params["down"], y, compute_dtype)
    return out, {"c": c, "n": n, "m": m_new}


# ---------------------------------------------------------------------------
# sLSTM (xLSTM's scalar-memory block) — inherently sequential
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class SLSTMSpec:
    d_model: int
    num_heads: int  # gates are per-head-block diagonal in the full xLSTM;
                    # we use full projections (simpler, strictly more general)


def slstm_init(key, spec: SLSTMSpec, dtype) -> dict:
    ks = jax.random.split(key, 6)
    d = spec.d_model
    return {
        "wz": layers.dense_init_bias(ks[0], d, d, dtype),
        "wi": layers.dense_init_bias(ks[1], d, d, dtype),
        "wf": layers.dense_init_bias(ks[2], d, d, dtype),
        "wo": layers.dense_init_bias(ks[3], d, d, dtype),
        # Recurrent weights.
        "rz": layers.truncated_normal_init(ks[4], (d, d), d**-0.5, dtype),
        "ri": layers.truncated_normal_init(
            jax.random.fold_in(key, 10), (d, d), d**-0.5, dtype
        ),
        "rf": layers.truncated_normal_init(
            jax.random.fold_in(key, 11), (d, d), d**-0.5, dtype
        ),
        "ro": layers.truncated_normal_init(ks[5], (d, d), d**-0.5, dtype),
        "out": layers.dense_init(jax.random.fold_in(key, 12), d, d, dtype),
    }


def slstm_init_state(batch: int, spec: SLSTMSpec, dtype) -> dict:
    d = spec.d_model
    z = jnp.zeros((batch, d), jnp.float32)
    return {"c": z, "n": z, "h": z, "m": jnp.full((batch, d), -jnp.inf)}


def _slstm_cell(params, x_t, state, compute_dtype):
    """One sLSTM step with exponential gating + stabilizer (xLSTM eqs.)."""
    hprev = state["h"].astype(compute_dtype)
    pre = lambda wk, rk: (
        layers.dense_apply(params[wk], x_t, jnp.float32)
        + (hprev @ params[rk].astype(compute_dtype)).astype(jnp.float32)
    )
    z = jnp.tanh(pre("wz", "rz"))
    itil = pre("wi", "ri")
    ftil = pre("wf", "rf")
    o = jax.nn.sigmoid(pre("wo", "ro"))
    logf = jax.nn.log_sigmoid(ftil)
    m_new = jnp.maximum(logf + state["m"], itil)
    iw = jnp.exp(itil - m_new)
    fw = jnp.exp(logf + state["m"] - m_new)
    c = fw * state["c"] + iw * z
    n = fw * state["n"] + iw
    h = o * (c / jnp.maximum(n, jnp.exp(-m_new) + 1e-6))
    return {"c": c, "n": n, "h": h, "m": m_new}


def slstm_apply_train(params, x, spec: SLSTMSpec, compute_dtype):
    """x: [B, S, D]; lax.scan over time (sLSTM has no parallel form)."""
    b, s, d = x.shape
    state0 = slstm_init_state(b, spec, compute_dtype)

    def step(state, x_t):
        new = _slstm_cell(params, x_t, state, compute_dtype)
        return new, new["h"]

    _, hs = jax.lax.scan(step, state0, jnp.swapaxes(x, 0, 1))
    y = jnp.swapaxes(hs, 0, 1).astype(compute_dtype)
    return layers.dense_apply(params["out"], y, compute_dtype)


def slstm_apply_decode(params, x, state, spec: SLSTMSpec, compute_dtype):
    new = _slstm_cell(params, x[:, 0], state, compute_dtype)
    y = new["h"].astype(compute_dtype)[:, None, :]
    return layers.dense_apply(params["out"], y, compute_dtype), new
