"""The unified decoder model: embed → scan over block groups → LM head.

Pure-functional API:
  init(cfg, key)                          -> params
  forward(cfg, params, inputs)            -> logits [B, S, V]
  loss(cfg, params, batch)                -> (scalar, metrics)
  prefill(cfg, params, inputs, max_len)   -> (last_logits, caches)
  decode_step(cfg, params, caches, token) -> (logits, caches)

``inputs`` is a dict: {"tokens": [B, S]} for LMs; the VLM backbone adds
{"patch_embeds": [B, P, D]} (precomputed by the stubbed vision frontend;
DESIGN.md §5), and the audio backbone consumes EnCodec token ids directly
(the codec itself is the stub).

Layers are scanned in groups of ``len(cfg.block_pattern)`` heterogeneous
blocks (stacked leading G axis), keeping HLO size O(pattern) instead of
O(num_layers) — essential for 512-device dry-run compiles.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import blocks, layers


def init(cfg: ModelConfig, key) -> dict:
    pdt = jnp.dtype(cfg.param_dtype)
    keys = jax.random.split(key, 4 + len(cfg.block_pattern))
    params: dict = {
        "embed": layers.embed_init(keys[0], cfg.vocab_size, cfg.d_model, pdt),
        "final_norm": layers.rmsnorm_init(cfg.d_model, pdt),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = layers.embed_init(
            keys[1], cfg.vocab_size, cfg.d_model, pdt
        )
    if cfg.frontend == "vision_patches":
        params["patch_proj"] = layers.dense_init(
            keys[2], cfg.d_model, cfg.d_model, pdt
        )

    # Stacked per-group block params: vmap init over the group axis.
    g = cfg.num_groups
    block_params = {}
    for i, kind in enumerate(cfg.block_pattern):
        ks = jax.random.split(keys[3 + i], g)
        block_params[f"b{i}_{kind}"] = jax.vmap(
            lambda k: blocks.init(k, cfg, kind)
        )(ks)
    params["blocks"] = block_params
    return params


def _embed_inputs(cfg: ModelConfig, params, inputs) -> jnp.ndarray:
    cdt = jnp.dtype(cfg.compute_dtype)
    x = layers.embed_apply(params["embed"], inputs["tokens"], cdt)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cdt)
    if cfg.frontend == "vision_patches":
        patches = layers.dense_apply(
            params["patch_proj"], inputs["patch_embeds"].astype(cdt), cdt
        )
        x = jnp.concatenate([patches, x], axis=1)
    return x


def _scan_groups(cfg: ModelConfig, params, x, remat: bool = True):
    from repro.models.sharding_hints import constrain

    pattern = cfg.block_pattern

    def group_body(x, gp):
        # NOTE on sequence parallelism: constraining the seq dim over the
        # TP axis here was tried and MEASURED WORSE (EXPERIMENTS.md §Perf,
        # refuted iteration): GSPMD resolves the boundary constraint with
        # extra reshard collectives instead of RS/AG fusion. Boundaries
        # are batch-pinned only.
        x = constrain(x, ("batch", None, None))
        aux_tot = dict(blocks.NO_AUX)
        for i, kind in enumerate(pattern):
            x, aux = blocks.apply_train(gp[f"b{i}_{kind}"], x, cfg, kind)
            aux_tot = {k: aux_tot[k] + aux[k] for k in aux_tot}
        return x, aux_tot

    # NOTE: jax.checkpoint(prevent_cse=False) was tried here and MEASURED
    # WORSE on collective bytes (EXPERIMENTS.md §Perf, refuted iteration);
    # the default barriers stay.
    body = jax.checkpoint(group_body) if remat else group_body
    x, auxs = jax.lax.scan(body, x, params["blocks"])
    aux = {k: jnp.sum(v) for k, v in auxs.items()}
    return x, aux


def forward(cfg: ModelConfig, params, inputs, remat: bool = True):
    """Training/scoring forward pass → (logits, aux_losses)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    x = _embed_inputs(cfg, params, inputs)
    x, aux = _scan_groups(cfg, params, x, remat=remat)
    x = layers.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps, cdt)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(table, x, cdt)
    logits = layers.softcap(
        logits.astype(jnp.float32), cfg.final_logit_softcap
    )
    return logits, aux


def loss(
    cfg: ModelConfig,
    params,
    batch,
    moe_aux_weight: float = 1e-2,
    router_z_weight: float = 1e-3,
    remat: bool = True,
):
    """Next-token cross-entropy. batch: {"tokens": [B, S+1], ...}.

    For the VLM backbone, patch positions are prepended by the model and
    excluded from the loss (labels cover text tokens only).
    """
    tokens = batch["tokens"]
    inputs = dict(batch)
    inputs["tokens"] = tokens[:, :-1]
    labels = tokens[:, 1:]

    logits, aux = forward(cfg, params, inputs, remat=remat)
    if cfg.frontend == "vision_patches":
        # Drop the prepended patch positions from the logits; next-token
        # prediction applies to the text stream only.
        logits = logits[:, inputs["patch_embeds"].shape[1]:, :]

    # Sharded-vocab cross entropy: log_softmax + take_along_axis gathers a
    # replicated [tokens, V] fp32 tensor when V is TP-sharded (measured
    # +26 GB/chip collectives on xlstm; §Perf). Instead reduce over the
    # vocab dim directly — XLA fuses the mask/exp into the reductions and
    # only [tokens]-sized partials cross shards.
    lg = logits.astype(jnp.float32)
    m = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lg - m), axis=-1)) + m[..., 0]
    vocab_iota = jnp.arange(lg.shape[-1], dtype=labels.dtype)
    label_logit = jnp.sum(
        jnp.where(vocab_iota[None, None, :] == labels[..., None], lg, 0.0),
        axis=-1,
    )
    nll = lse - label_logit
    ce = jnp.mean(nll)
    total = (
        ce
        + moe_aux_weight * aux["load_balance_loss"]
        + router_z_weight * aux["router_z_loss"]
    )
    metrics = {"ce": ce, **aux}
    return total, metrics


def init_caches(cfg: ModelConfig, batch: int, max_len: int):
    """Stacked decode caches: one pytree per pattern position, [G, ...]."""
    g = cfg.num_groups

    def stack(c):
        return jax.tree.map(lambda a: jnp.stack([a] * g), c)

    return {
        f"b{i}_{kind}": stack(blocks.init_cache(batch, max_len, cfg, kind))
        for i, kind in enumerate(cfg.block_pattern)
    }


def prefill(cfg: ModelConfig, params, inputs, max_len: int):
    """Process the prompt, return (logits at last position, caches)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    pattern = cfg.block_pattern
    x = _embed_inputs(cfg, params, inputs)

    def group_body(x, gp):
        caches = {}
        for i, kind in enumerate(pattern):
            x, caches[f"b{i}_{kind}"] = blocks.prefill(
                gp[f"b{i}_{kind}"], x, cfg, kind, max_len
            )
        return x, caches

    x, caches = jax.lax.scan(group_body, x, params["blocks"])
    x = layers.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps, cdt)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(table, x[:, -1:, :], cdt)
    logits = layers.softcap(
        logits.astype(jnp.float32), cfg.final_logit_softcap
    )
    return logits, caches


def decode_step(cfg: ModelConfig, params, caches, token):
    """One decode step. token: [B, 1] int32 → (logits [B,1,V], caches)."""
    cdt = jnp.dtype(cfg.compute_dtype)
    pattern = cfg.block_pattern
    x = layers.embed_apply(params["embed"], token, cdt)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.d_model**0.5, cdt)

    def group_body(x, scanned):
        gp, gc = scanned
        new_c = {}
        for i, kind in enumerate(pattern):
            key = f"b{i}_{kind}"
            x, new_c[key] = blocks.apply_decode(gp[key], x, gc[key], cfg, kind)
        return x, new_c

    x, new_caches = jax.lax.scan(group_body, x, (params["blocks"], caches))
    x = layers.rmsnorm_apply(params["final_norm"], x, cfg.norm_eps, cdt)
    table = params["embed"] if cfg.tie_embeddings else params["unembed"]
    logits = layers.unembed_apply(table, x, cdt)
    logits = layers.softcap(
        logits.astype(jnp.float32), cfg.final_logit_softcap
    )
    return logits, new_caches


def parameter_count(cfg: ModelConfig, params=None) -> int:
    import math

    if params is None:
        params = jax.eval_shape(lambda k: init(cfg, k), jax.random.key(0))
    return sum(math.prod(l.shape) for l in jax.tree.leaves(params))
