"""Mixture-of-Experts FFN with top-k routing and sort-based dispatch.

TPU-native, static-shape formulation: tokens are routed to experts by
sorting each row's (token, choice) list by expert id; dispatch/combine
are expressed as row-wise GATHERS (``take_along_axis``), with scatters
confined to small integer index vectors — GSPMD partitions batched
gathers cleanly, while scatters on [*, D] tensors were measured
replicating 43 GB dispatch buffers at prefill scale.

The batch dim is handled explicitly (no vmap) so every wide intermediate
([B, E·C, D], [B, E, C, F]) can be pinned to the batch sharding via
repro.models.sharding_hints. Capacity C = ceil(S·top_k/E·cf) per row;
overflow tokens are dropped (standard). Aux: Switch load-balance +
router z-loss.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.models.sharding_hints import constrain


@dataclasses.dataclass(frozen=True)
class MoESpec:
    d_model: int
    d_ff: int
    num_experts: int
    top_k: int
    capacity_factor: float = 1.25


def init(key, spec: MoESpec, dtype) -> dict:
    kr, kg, ku, kd = jax.random.split(key, 4)
    e, d, f = spec.num_experts, spec.d_model, spec.d_ff
    return {
        "router": layers.dense_init(kr, d, e, dtype),
        # Stacked expert SwiGLU weights: [E, d, f] / [E, f, d].
        "gate": layers.truncated_normal_init(kg, (e, d, f), d**-0.5, dtype),
        "up": layers.truncated_normal_init(ku, (e, d, f), d**-0.5, dtype),
        "down": layers.truncated_normal_init(kd, (e, f, d), f**-0.5, dtype),
    }


def capacity(tokens: int, spec: MoESpec) -> int:
    c = int(tokens * spec.top_k / spec.num_experts * spec.capacity_factor)
    return max(c, spec.top_k)


def apply(
    params: dict, x: jnp.ndarray, spec: MoESpec, compute_dtype
) -> tuple[jnp.ndarray, dict]:
    """x: [B, S, D] -> (y, aux); aux = {load_balance_loss, router_z_loss}.

    Dispatch groups are batch rows: capacity is per row and routing never
    crosses rows, so under batch sharding all index math stays on-chip.
    """
    b, n, d = x.shape
    e, k = spec.num_experts, spec.top_k
    cap = capacity(n, spec)
    xt = constrain(x.astype(compute_dtype), ("batch", None, None))

    router_logits = layers.dense_apply(params["router"], xt, jnp.float32)
    probs = jax.nn.softmax(router_logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)          # [b, n, k]
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # ---- per-row sort by expert id --------------------------------------
    flat_expert = expert_idx.reshape(b, n * k)
    order = jnp.argsort(flat_expert, axis=-1)                # [b, nk]
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=-1)
    # Position within each expert's run: index − first index of the run.
    ar = jnp.broadcast_to(jnp.arange(n * k), (b, n * k))
    change = jnp.concatenate(
        [
            jnp.ones((b, 1), bool),
            sorted_expert[:, 1:] != sorted_expert[:, :-1],
        ],
        axis=-1,
    )
    run_start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(change, ar, 0), axis=-1
    )
    positions = ar - run_start
    keep = positions < cap
    slot = sorted_expert * cap + positions                   # [b, nk]

    # slot -> source token (int scatter per row; sentinel n drops).
    rows = jnp.arange(b)[:, None]
    token_for_slot = jnp.full((b, e * cap), n, jnp.int32)
    token_for_slot = token_for_slot.at[
        rows, jnp.where(keep, slot, e * cap)
    ].set((order // k).astype(jnp.int32), mode="drop", unique_indices=True)
    # (token, choice) -> slot (sentinel E·C).
    slot_for_choice = jnp.full((b, n * k), e * cap, jnp.int32)
    slot_for_choice = slot_for_choice.at[rows, order].set(
        jnp.where(keep, slot, e * cap).astype(jnp.int32),
        unique_indices=True,
    )

    # ---- dispatch gather -------------------------------------------------
    xt_pad = jnp.concatenate(
        [xt, jnp.zeros((b, 1, d), compute_dtype)], axis=1
    )
    xin = jnp.take_along_axis(
        xt_pad, token_for_slot[..., None], axis=1
    )                                                        # [b, E·C, d]
    xin = constrain(xin, ("batch", None, None)).reshape(b, e, cap, d)

    # ---- expert SwiGLU ---------------------------------------------------
    # Prefill-scale groups: loop experts sequentially (same FLOPs, E× less
    # live memory); training-scale groups stay vectorized for EP.
    if cap * spec.d_ff > 128 * 1024 * 1024:
        def one_expert(args):
            xe, wg, wu, wd = args                            # xe: [b,cap,d]
            g = jax.nn.silu(xe @ wg.astype(compute_dtype))
            u = xe @ wu.astype(compute_dtype)
            return (g * u) @ wd.astype(compute_dtype)

        yout = jax.lax.map(
            one_expert,
            (
                jnp.moveaxis(xin, 1, 0),
                params["gate"], params["up"], params["down"],
            ),
        )                                                    # [e, b, cap, d]
        yout = jnp.moveaxis(yout, 0, 1)
    else:
        gate = jax.nn.silu(
            jnp.einsum(
                "becd,edf->becf", xin, params["gate"].astype(compute_dtype)
            )
        )
        up = jnp.einsum(
            "becd,edf->becf", xin, params["up"].astype(compute_dtype)
        )
        yout = jnp.einsum(
            "becf,efd->becd", gate * up,
            params["down"].astype(compute_dtype),
        )
    yout = constrain(
        yout.reshape(b, e * cap, d), ("batch", None, None)
    )

    # ---- combine gather ---------------------------------------------------
    yout_pad = jnp.concatenate(
        [yout, jnp.zeros((b, 1, d), compute_dtype)], axis=1
    )
    per_choice = jnp.take_along_axis(
        yout_pad, slot_for_choice[..., None], axis=1
    ).reshape(b, n, k, d)
    y = jnp.einsum(
        "bnk,bnkd->bnd", gate_vals.astype(compute_dtype), per_choice
    )
    y = constrain(y, ("batch", None, None))

    # ---- aux losses --------------------------------------------------------
    me = jnp.mean(
        jax.nn.one_hot(expert_idx[..., 0], e, dtype=jnp.float32),
        axis=(0, 1),
    )
    ce = jnp.mean(probs, axis=(0, 1))
    aux = {
        "load_balance_loss": e * jnp.sum(me * ce),
        "router_z_loss": jnp.mean(
            jnp.square(jax.nn.logsumexp(router_logits, axis=-1))
        ),
    }
    return y, aux


def apply_dense_reference(
    params: dict, x: jnp.ndarray, spec: MoESpec, compute_dtype
) -> jnp.ndarray:
    """No-capacity loop-over-experts oracle (tests only; O(n·E·d·f))."""
    b, s, d = x.shape
    xt = x.reshape(-1, d).astype(compute_dtype)
    logits = layers.dense_apply(params["router"], xt, jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, spec.top_k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)
    y = jnp.zeros_like(xt)
    for ei in range(spec.num_experts):
        g = jax.nn.silu(xt @ params["gate"][ei].astype(compute_dtype))
        u = xt @ params["up"][ei].astype(compute_dtype)
        o = (g * u) @ params["down"][ei].astype(compute_dtype)
        w = jnp.sum(
            jnp.where(expert_idx == ei, gate_vals, 0.0), axis=-1
        ).astype(compute_dtype)
        y = y + o * w[:, None]
    return y.reshape(b, s, d)
