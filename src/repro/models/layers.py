"""Shared neural-net layers (pure JAX, no flax): norms, projections, RoPE.

All parameters are plain pytrees of jnp arrays. Initializers take an
explicit key. ``param_dtype`` controls storage, ``compute_dtype`` the
activation math (mixed precision: bf16 compute is the TPU default).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


def truncated_normal_init(key, shape, scale, dtype):
    # 1/sqrt(fan_in)-style scaling is applied by callers via `scale`.
    return (scale * jax.random.truncated_normal(key, -2.0, 2.0, shape)).astype(
        dtype
    )


def dense_init(key, d_in: int, d_out: int, dtype) -> dict:
    w = truncated_normal_init(key, (d_in, d_out), d_in**-0.5, dtype)
    return {"kernel": w}


def dense_init_bias(key, d_in: int, d_out: int, dtype) -> dict:
    p = dense_init(key, d_in, d_out, dtype)
    p["bias"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(params: dict, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    y = x.astype(compute_dtype) @ params["kernel"].astype(compute_dtype)
    if "bias" in params:
        y = y + params["bias"].astype(compute_dtype)
    return y


def embed_init(key, vocab: int, d_model: int, dtype) -> dict:
    return {
        "table": truncated_normal_init(
            key, (vocab, d_model), d_model**-0.5, dtype
        )
    }


def embed_apply(params: dict, ids: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return params["table"].astype(compute_dtype)[ids]


def unembed_apply(params: dict, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    """Project to vocab logits with the (possibly tied) embedding table."""
    return jnp.einsum(
        "...d,vd->...v", x.astype(compute_dtype),
        params["table"].astype(compute_dtype),
    )


def rmsnorm_init(d: int, dtype) -> dict:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(
    params: dict, x: jnp.ndarray, eps: float, compute_dtype
) -> jnp.ndarray:
    # Normalize in fp32 for stability, multiply in compute dtype.
    x32 = x.astype(jnp.float32)
    rms = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * rms).astype(compute_dtype) * params["scale"].astype(
        compute_dtype
    )


def softcap(x: jnp.ndarray, cap: float | None) -> jnp.ndarray:
    """Gemma2-style logit soft-capping: cap·tanh(x/cap)."""
    if cap is None:
        return x
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    exponents = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponents)  # [head_dim/2]


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float
) -> jnp.ndarray:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    freqs = rope_frequencies(x.shape[-1], theta)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..,S,hd/2]
    cos = jnp.cos(angles)[..., None, :]  # broadcast over heads
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, d_model: int, d_ff: int, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "up": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp_apply(params: dict, x: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    gate = jax.nn.silu(dense_apply(params["gate"], x, compute_dtype))
    up = dense_apply(params["up"], x, compute_dtype)
    return dense_apply(params["down"], gate * up, compute_dtype)
