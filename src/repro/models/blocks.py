"""Per-kind residual blocks with a unified (init / train / decode) API.

Every kind exposes:
  init(key, cfg, kind)              -> params
  apply_train(params, x, cfg, kind) -> (x, aux_losses)
  init_cache(batch, max_len, cfg, kind, dtype) -> cache
  apply_decode(params, x, cache, cfg, kind)    -> (x, cache)
  prefill(params, x, cfg, kind, max_len)       -> (x, cache)

so the model can scan over heterogeneous groups uniformly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import MOE_KINDS, ModelConfig
from repro.models import attention, layers, moe, ssm


def _attn_spec(cfg: ModelConfig, kind: str) -> attention.AttnSpec:
    window = None
    if kind in ("swa", "swa_moe", "local"):
        window = cfg.sliding_window
    return attention.AttnSpec(
        d_model=cfg.d_model,
        num_heads=cfg.num_heads,
        num_kv_heads=cfg.num_kv_heads,
        head_dim=cfg.resolved_head_dim,
        window=window,
        rope_theta=cfg.rope_theta,
        softcap=cfg.attn_logit_softcap,
        qkv_bias=cfg.qkv_bias,
    )


def _mamba_spec(cfg: ModelConfig) -> ssm.MambaSpec:
    return ssm.MambaSpec(
        d_model=cfg.d_model,
        d_state=cfg.ssm_state_dim,
        d_conv=cfg.ssm_conv_dim,
        expand=cfg.ssm_expand,
    )


def _moe_spec(cfg: ModelConfig) -> moe.MoESpec:
    return moe.MoESpec(
        d_model=cfg.d_model,
        d_ff=cfg.d_ff,
        num_experts=cfg.num_experts,
        top_k=cfg.num_experts_per_token,
        capacity_factor=cfg.capacity_factor,
    )


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype), jnp.dtype(cfg.compute_dtype)


def _is_attn(kind: str) -> bool:
    return kind in ("attn", "attn_moe", "swa", "swa_moe", "local", "global")


def _has_ffn(kind: str) -> bool:
    return kind not in ("mlstm", "slstm")


NO_AUX = {
    "load_balance_loss": jnp.zeros((), jnp.float32),
    "router_z_loss": jnp.zeros((), jnp.float32),
}


def init(key, cfg: ModelConfig, kind: str) -> dict:
    pdt, _ = _dtype(cfg)
    kmix, kffn = jax.random.split(key)
    p: dict = {"norm1": layers.rmsnorm_init(cfg.d_model, pdt)}
    if _is_attn(kind):
        p["mixer"] = attention.init(kmix, _attn_spec(cfg, kind), pdt)
    elif kind in ("mamba", "mamba_moe"):
        p["mixer"] = ssm.mamba_init(kmix, _mamba_spec(cfg), pdt)
    elif kind == "mlstm":
        p["mixer"] = ssm.mlstm_init(
            kmix, ssm.MLSTMSpec(cfg.d_model, cfg.mlstm_heads), pdt
        )
    elif kind == "slstm":
        p["mixer"] = ssm.slstm_init(
            kmix, ssm.SLSTMSpec(cfg.d_model, cfg.mlstm_heads), pdt
        )
    else:
        raise ValueError(kind)
    if _has_ffn(kind):
        p["norm2"] = layers.rmsnorm_init(cfg.d_model, pdt)
        if kind in MOE_KINDS:
            p["ffn"] = moe.init(kffn, _moe_spec(cfg), pdt)
        else:
            p["ffn"] = layers.mlp_init(kffn, cfg.d_model, cfg.d_ff, pdt)
    return p


def _mixer_train(params, x, cfg: ModelConfig, kind: str, cdt):
    if _is_attn(kind):
        return attention.apply_train(params, x, _attn_spec(cfg, kind), cdt)
    if kind in ("mamba", "mamba_moe"):
        return ssm.mamba_apply_train(params, x, _mamba_spec(cfg), cdt)
    if kind == "mlstm":
        return ssm.mlstm_apply_train(
            params, x, ssm.MLSTMSpec(cfg.d_model, cfg.mlstm_heads), cdt
        )
    if kind == "slstm":
        return ssm.slstm_apply_train(
            params, x, ssm.SLSTMSpec(cfg.d_model, cfg.mlstm_heads), cdt
        )
    raise ValueError(kind)


def apply_train(params, x, cfg: ModelConfig, kind: str):
    _, cdt = _dtype(cfg)
    h = layers.rmsnorm_apply(params["norm1"], x, cfg.norm_eps, cdt)
    x = x + _mixer_train(params["mixer"], h, cfg, kind, cdt)
    aux = dict(NO_AUX)
    if _has_ffn(kind):
        h = layers.rmsnorm_apply(params["norm2"], x, cfg.norm_eps, cdt)
        if kind in MOE_KINDS:
            y, aux = moe.apply(params["ffn"], h, _moe_spec(cfg), cdt)
        else:
            y = layers.mlp_apply(params["ffn"], h, cdt)
        x = x + y
    return x, aux


def init_cache(batch: int, max_len: int, cfg: ModelConfig, kind: str):
    _, cdt = _dtype(cfg)
    if _is_attn(kind):
        return attention.init_cache(batch, max_len, _attn_spec(cfg, kind), cdt)
    if kind in ("mamba", "mamba_moe"):
        return ssm.mamba_init_state(batch, _mamba_spec(cfg), cdt)
    if kind == "mlstm":
        return ssm.mlstm_init_state(
            batch, ssm.MLSTMSpec(cfg.d_model, cfg.mlstm_heads), cdt
        )
    if kind == "slstm":
        return ssm.slstm_init_state(
            batch, ssm.SLSTMSpec(cfg.d_model, cfg.mlstm_heads), cdt
        )
    raise ValueError(kind)


def apply_decode(params, x, cache, cfg: ModelConfig, kind: str):
    _, cdt = _dtype(cfg)
    h = layers.rmsnorm_apply(params["norm1"], x, cfg.norm_eps, cdt)
    if _is_attn(kind):
        y, cache = attention.apply_decode(
            params["mixer"], h, cache, _attn_spec(cfg, kind), cdt
        )
    elif kind in ("mamba", "mamba_moe"):
        y, cache = ssm.mamba_apply_decode(
            params["mixer"], h, cache, _mamba_spec(cfg), cdt
        )
    elif kind == "mlstm":
        y, cache = ssm.mlstm_apply_decode(
            params["mixer"], h, cache,
            ssm.MLSTMSpec(cfg.d_model, cfg.mlstm_heads), cdt,
        )
    elif kind == "slstm":
        y, cache = ssm.slstm_apply_decode(
            params["mixer"], h, cache,
            ssm.SLSTMSpec(cfg.d_model, cfg.mlstm_heads), cdt,
        )
    else:
        raise ValueError(kind)
    x = x + y
    if _has_ffn(kind):
        h = layers.rmsnorm_apply(params["norm2"], x, cfg.norm_eps, cdt)
        if kind in MOE_KINDS:
            y, _ = moe.apply(params["ffn"], h, _moe_spec(cfg), cdt)
        else:
            y = layers.mlp_apply(params["ffn"], h, cdt)
        x = x + y
    return x, cache


def prefill(params, x, cfg: ModelConfig, kind: str, max_len: int):
    """Full-sequence pass that also returns the decode cache."""
    _, cdt = _dtype(cfg)
    h = layers.rmsnorm_apply(params["norm1"], x, cfg.norm_eps, cdt)
    if _is_attn(kind):
        y, cache = attention.prefill_cache(
            params["mixer"], h, _attn_spec(cfg, kind), cdt, max_len
        )
    else:
        # Recurrent kinds: run the train form token-parallel where possible
        # and rebuild the final state by stepping (exact but O(S) steps) —
        # for performance-critical serving the state is produced by the
        # chunked prefill in repro.launch.serve. Here: step-by-step.
        b, s, _ = x.shape
        cache = init_cache(b, max_len, cfg, kind)
        h_all = _mixer_train(params["mixer"], h, cfg, kind, cdt)

        def step(c, ht):
            _, c2 = _mixer_decode_only(params["mixer"], ht[:, None, :], c, cfg, kind, cdt)
            return c2, None

        cache, _ = jax.lax.scan(step, cache, jnp.swapaxes(h, 0, 1))
        y = h_all
    x = x + y
    aux = dict(NO_AUX)
    if _has_ffn(kind):
        h2 = layers.rmsnorm_apply(params["norm2"], x, cfg.norm_eps, cdt)
        if kind in MOE_KINDS:
            y2, aux = moe.apply(params["ffn"], h2, _moe_spec(cfg), cdt)
        else:
            y2 = layers.mlp_apply(params["ffn"], h2, cdt)
        x = x + y2
    return x, cache


def _mixer_decode_only(params, x, cache, cfg, kind, cdt):
    if kind in ("mamba", "mamba_moe"):
        return ssm.mamba_apply_decode(params, x, cache, _mamba_spec(cfg), cdt)
    if kind == "mlstm":
        return ssm.mlstm_apply_decode(
            params, x, cache, ssm.MLSTMSpec(cfg.d_model, cfg.mlstm_heads), cdt
        )
    if kind == "slstm":
        return ssm.slstm_apply_decode(
            params, x, cache, ssm.SLSTMSpec(cfg.d_model, cfg.mlstm_heads), cdt
        )
    raise ValueError(kind)
