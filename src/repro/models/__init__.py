"""Model substrate: unified decoder over heterogeneous block patterns."""
