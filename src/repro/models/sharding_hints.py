"""Opt-in activation sharding hints for mesh-agnostic model code.

The launch layer knows the mesh ("data"/"model"/"pod" axes); the model
only knows logical roles ("batch", "seq", "tp"). ``set_hints`` installs a
role→axes map; ``constrain`` then pins named dims with
``with_sharding_constraint``. With no hints installed (unit tests, single
device) it is a no-op, so model code can call it unconditionally.

Measured motivation: GSPMD replicated the vmapped MoE dispatch buffers
([B, E·C, D] ≈ 43 GB/chip) in the prefill_32k lowering; pinning the batch
dim restores batch sharding (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import PartitionSpec as P

_HINTS: contextvars.ContextVar[dict | None] = contextvars.ContextVar(
    "sharding_hints", default=None
)


@contextlib.contextmanager
def hints(role_axes: dict):
    """role_axes, e.g. {"batch": ("data",), "tp": ("model",)}."""
    token = _HINTS.set(dict(role_axes))
    try:
        yield
    finally:
        _HINTS.reset(token)


def constrain(x, roles: tuple):
    """roles: per-dim role name or None, e.g. ("batch", "seq", None).

    Divisibility-guarded: a role is dropped if the dim does not divide
    the axes' size (never rely on GSPMD padding)."""
    mapping = _HINTS.get()
    if mapping is None:
        return x
    from repro.launch import mesh as _  # noqa: F401 (no-op, doc link)

    mesh = None
    try:
        mesh = jax.sharding.get_abstract_mesh()
    except Exception:
        pass
    spec = []
    for dim, r in enumerate(roles):
        axes = mapping.get(r) if r else None
        if axes and mesh is not None:
            size = 1
            for a in axes:
                size *= dict(zip(mesh.axis_names, mesh.axis_sizes)).get(a, 1)
            if size <= 1 or x.shape[dim] % size or x.shape[dim] < size:
                axes = None
        if not axes:
            spec.append(None)
        elif len(axes) == 1:
            spec.append(axes[0])
        else:
            spec.append(tuple(axes))
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x  # no mesh context: best-effort no-op
