"""GQA attention: full / sliding-window / local-global, train + decode.

Reference (jnp) implementation used for training, prefill, CPU smoke tests
and for the dry-run lowering. The Pallas flash kernels in repro.kernels
implement the same math for TPU and are validated against this module.

Cache layout (per layer): {"k": [B, S_cache, H_kv, Dh], "v": same,
"pos": scalar int32 next write position}. Sliding-window layers allocate
S_cache = window and write round-robin; global layers allocate the full
context.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import layers

NEG_INF = -2.0e38


@dataclasses.dataclass(frozen=True)
class AttnSpec:
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    window: int | None         # None = full causal
    rope_theta: float
    softcap: float | None      # attention-logit softcap (gemma2)
    qkv_bias: bool


def init(key, spec: AttnSpec, dtype) -> dict:
    kq, kk, kv, ko = jax.random.split(key, 4)
    mk = layers.dense_init_bias if spec.qkv_bias else layers.dense_init
    return {
        "wq": mk(kq, spec.d_model, spec.num_heads * spec.head_dim, dtype),
        "wk": mk(kk, spec.d_model, spec.num_kv_heads * spec.head_dim, dtype),
        "wv": mk(kv, spec.d_model, spec.num_kv_heads * spec.head_dim, dtype),
        "wo": layers.dense_init(
            ko, spec.num_heads * spec.head_dim, spec.d_model, dtype
        ),
    }


def _project_qkv(params, x, spec: AttnSpec, positions, compute_dtype):
    b, s, _ = x.shape
    q = layers.dense_apply(params["wq"], x, compute_dtype).reshape(
        b, s, spec.num_heads, spec.head_dim
    )
    k = layers.dense_apply(params["wk"], x, compute_dtype).reshape(
        b, s, spec.num_kv_heads, spec.head_dim
    )
    v = layers.dense_apply(params["wv"], x, compute_dtype).reshape(
        b, s, spec.num_kv_heads, spec.head_dim
    )
    if spec.rope_theta > 0:  # theta == 0 ⇒ NoPE (e.g. Jamba attention)
        q = layers.apply_rope(q, positions, spec.rope_theta)
        k = layers.apply_rope(k, positions, spec.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, spec: AttnSpec, compute_dtype):
    """Grouped scaled-dot-product attention. q:[B,Sq,H,D] k/v:[B,Sk,Hkv,D]."""
    groups = spec.num_heads // spec.num_kv_heads
    b, sq, h, d = q.shape
    qg = q.reshape(b, sq, spec.num_kv_heads, groups, d)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * (d**-0.5)
    logits = layers.softcap(logits, spec.softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(compute_dtype), v)
    return out.reshape(b, sq, h, d)


def causal_mask(sq: int, sk: int, window: int | None) -> jnp.ndarray:
    """[sq, sk] boolean; True = attend. Optionally sliding-window limited."""
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    return mask


# Sequences at or above this length use the chunked (flash-style) path:
# the monolithic [Sq, Sk] logits tensor would not fit HBM.
CHUNKED_ATTN_THRESHOLD = 8192
CHUNK_Q = 1024
CHUNK_K = 1024


def _sdpa_chunked(q, k, v, spec: AttnSpec, compute_dtype, window):
    """Online-softmax attention in pure jnp: scan over k chunks inside a
    scan over q chunks. Never materializes more than [B, H, CQ, CK]
    logits — the jnp analogue of the Pallas flash kernel (same math)."""
    b, s, h, d = q.shape
    kv = spec.num_kv_heads
    groups = h // kv
    cq, ck = min(CHUNK_Q, s), min(CHUNK_K, s)
    nq, nk = s // cq, s // ck
    qg = q.reshape(b, nq, cq, kv, groups, d).astype(jnp.float32)
    kg = k.reshape(b, nk, ck, kv, d).astype(jnp.float32)
    vg = v.reshape(b, nk, ck, kv, d).astype(jnp.float32)

    def q_block(iq, q_blk):
        # q_blk: [b, cq, kv, groups, d]
        def k_step(carry, ik_blk):
            m_prev, l_prev, acc = carry
            ik, k_blk, v_blk = ik_blk
            logits = jnp.einsum(
                "bqkgd,bskd->bkgqs", q_blk, k_blk
            ) * (d**-0.5)
            logits = layers.softcap(logits, spec.softcap)
            qpos = iq * cq + jnp.arange(cq)
            kpos = ik * ck + jnp.arange(ck)
            mask = kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            logits = jnp.where(mask[None, None, None], logits, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(logits, axis=-1))
            p = jnp.exp(logits - m_new[..., None])
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + jnp.sum(p, axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, v_blk
            )
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, kv, groups, cq), -jnp.inf)
        l0 = jnp.zeros((b, kv, groups, cq))
        a0 = jnp.zeros((b, kv, groups, cq, d))
        (m_f, l_f, acc), _ = jax.lax.scan(
            k_step,
            (m0, l0, a0),
            (jnp.arange(nk), jnp.moveaxis(kg, 1, 0), jnp.moveaxis(vg, 1, 0)),
        )
        out = acc / jnp.maximum(l_f[..., None], 1e-30)
        return jnp.moveaxis(out, 3, 1)  # [b, cq, kv, groups, d]

    out = jax.lax.map(
        lambda args: q_block(*args),
        (jnp.arange(nq), jnp.moveaxis(qg, 1, 0)),
    )  # [nq, b, cq, kv, groups, d]
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, d)
    return out.astype(compute_dtype)


def apply_train(
    params, x, spec: AttnSpec, compute_dtype, window_override=None
) -> jnp.ndarray:
    """Full-sequence training/prefill attention. x: [B, S, D]."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, x, spec, positions, compute_dtype)
    window = spec.window if window_override is None else window_override
    if s >= CHUNKED_ATTN_THRESHOLD and s % CHUNK_Q == 0 and s % CHUNK_K == 0:
        out = _sdpa_chunked(q, k, v, spec, compute_dtype, window)
    else:
        mask = jnp.broadcast_to(causal_mask(s, s, window), (b, s, s))
        out = _sdpa(q, k, v, mask, spec, compute_dtype)
    return layers.dense_apply(
        params["wo"], out.reshape(b, s, -1), compute_dtype
    )


def init_cache(
    batch: int, max_len: int, spec: AttnSpec, dtype
) -> dict:
    s_cache = min(max_len, spec.window) if spec.window else max_len
    shape = (batch, s_cache, spec.num_kv_heads, spec.head_dim)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "pos": jnp.zeros((), jnp.int32),
    }


def apply_decode(
    params, x, cache, spec: AttnSpec, compute_dtype
) -> tuple[jnp.ndarray, dict]:
    """Single-token decode. x: [B, 1, D]; cache as from ``init_cache``.

    Sliding-window layers use the cache as a ring buffer (slot = pos mod
    window); global layers append at pos. Positions are the true token
    positions, so RoPE is correct in both cases.
    """
    b, one, _ = x.shape
    pos = cache["pos"]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k_new, v_new = _project_qkv(params, x, spec, positions, compute_dtype)

    s_cache = cache["k"].shape[1]
    slot = pos % s_cache if spec.window is not None else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)

    # Valid-key mask: ring buffer ⇒ every slot < min(pos+1, S_cache) valid;
    # global ⇒ slots ≤ pos valid.
    idx = jnp.arange(s_cache)[None, :]
    if spec.window is not None:
        valid = idx < jnp.minimum(pos + 1, s_cache)
    else:
        valid = idx <= pos
    mask = jnp.broadcast_to(valid, (b, s_cache))[:, None, :]  # [B,1,Sk]

    out = _sdpa_decode(q, k, v, mask, spec, compute_dtype)
    out = layers.dense_apply(
        params["wo"], out.reshape(b, 1, -1), compute_dtype
    )
    return out, {"k": k, "v": v, "pos": pos + 1}


def _sdpa_decode(q, k, v, mask, spec: AttnSpec, compute_dtype):
    """Decode needs rope on cached K at their *stored* positions; we store
    K post-rope (written in apply_decode/prefill), so plain SDPA applies."""
    groups = spec.num_heads // spec.num_kv_heads
    b, sq, h, d = q.shape
    qg = q.reshape(b, sq, spec.num_kv_heads, groups, d)
    logits = jnp.einsum(
        "bqkgd,bskd->bkgqs", qg.astype(jnp.float32), k.astype(jnp.float32)
    ) * (d**-0.5)
    logits = layers.softcap(logits, spec.softcap)
    logits = jnp.where(mask[:, None, None, :, :], logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", probs.astype(compute_dtype), v)
    return out.reshape(b, sq, h, d)


def prefill_cache(
    params, x, spec: AttnSpec, compute_dtype, max_len: int
) -> tuple[jnp.ndarray, dict]:
    """Run full-sequence attention AND build the decode cache. x:[B,S,D]."""
    b, s, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _project_qkv(params, x, spec, positions, compute_dtype)
    window = spec.window
    if s >= CHUNKED_ATTN_THRESHOLD and s % CHUNK_Q == 0 and s % CHUNK_K == 0:
        out = _sdpa_chunked(q, k, v, spec, compute_dtype, window)
    else:
        mask = jnp.broadcast_to(causal_mask(s, s, window), (b, s, s))
        out = _sdpa(q, k, v, mask, spec, compute_dtype)
    y = layers.dense_apply(params["wo"], out.reshape(b, s, -1), compute_dtype)

    cache = init_cache(b, max_len, spec, compute_dtype)
    s_cache = cache["k"].shape[1]
    if spec.window is not None and s >= s_cache:
        # Keep the last `window` keys, aligned to ring-buffer slots.
        tail = s - s_cache
        ks, vs = k[:, tail:], v[:, tail:]
        # slot of absolute position p is p % s_cache
        perm = (jnp.arange(s_cache) + tail) % s_cache
        inv = jnp.argsort(perm)
        cache_k = ks[:, inv]
        cache_v = vs[:, inv]
    else:
        pad = s_cache - s
        cache_k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        cache_v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {
        "k": cache_k.astype(compute_dtype),
        "v": cache_v.astype(compute_dtype),
        "pos": jnp.asarray(s, jnp.int32),
    }
    return y, cache
